//! Artifact execution layer: manifest parsing plus the PJRT-backed
//! [`Runtime`] (XLA path) and its worker-pool [`ClientEngine`]
//! (engine::XlaEngine).
//!
//! The PJRT bindings are an external crate that the offline build cannot
//! fetch, so the execution half is feature-gated: `--features xla`
//! compiles `pjrt` against the vendored `xla` crate; the default build
//! substitutes the API-compatible `stub`, which parses manifests fine
//! but refuses to execute. Everything downstream (engine, exp drivers,
//! CLI) compiles identically either way.
//!
//! [`ClientEngine`]: crate::fl::ClientEngine

pub mod engine;
pub mod manifest;

/// Error type of the runtime layer (kept as plain strings so the stub and
/// the PJRT build share one signature without an error-crate dependency).
pub type RtResult<T> = Result<T, String>;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{Literal, ParamLiterals, Runtime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Literal, ParamLiterals, Runtime};
