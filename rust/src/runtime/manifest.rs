//! `artifacts/manifest.json` schema — the contract between `aot.py` (L2)
//! and the rust runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Shape+name of one parameter tensor (order matters: it is the AOT
/// entry-point argument order and the layout of the flat parameter vec).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
}

/// One AOT model's manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelManifest {
    pub name: String,
    pub kind: String,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub init_params: PathBuf,
    pub params: Vec<ParamSpec>,
    pub num_params: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: String, // "f32" | "i32"
    pub num_classes: usize,
    pub batch_size: usize,
    pub eval_batch: usize,
    pub use_pallas: bool,
}

impl ModelManifest {
    fn from_json(name: &str, dir: &Path, v: &Json) -> Result<Self, String> {
        let get_usize = |k: &str| {
            v.get(k)
                .as_usize()
                .ok_or_else(|| format!("manifest[{name}].{k} missing"))
        };
        let get_str = |k: &str| {
            v.get(k)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("manifest[{name}].{k} missing"))
        };
        let params = v
            .get("params")
            .as_arr()
            .ok_or_else(|| format!("manifest[{name}].params missing"))?
            .iter()
            .map(|p| {
                let shape: Vec<usize> = p
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .as_str()
                        .ok_or("param.name missing")?
                        .to_string(),
                    size: p.get("size").as_usize().ok_or("param.size missing")?,
                    shape,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let m = ModelManifest {
            name: name.to_string(),
            kind: get_str("kind")?,
            train_hlo: dir.join(get_str("train_hlo")?),
            eval_hlo: dir.join(get_str("eval_hlo")?),
            init_params: dir.join(get_str("init_params")?),
            num_params: get_usize("num_params")?,
            input_shape: v
                .get("input_shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            input_dtype: get_str("input_dtype")?,
            num_classes: get_usize("num_classes")?,
            batch_size: get_usize("batch_size")?,
            eval_batch: get_usize("eval_batch")?,
            use_pallas: v.get("use_pallas").as_bool().unwrap_or(false),
            params,
        };
        let total: usize = m.params.iter().map(|p| p.size).sum();
        if total != m.num_params {
            return Err(format!(
                "manifest[{name}]: param sizes sum {total} != num_params {}",
                m.num_params
            ));
        }
        for p in &m.params {
            let prod: usize = p.shape.iter().product();
            if prod != p.size {
                return Err(format!(
                    "manifest[{name}].{}: shape {:?} != size {}",
                    p.name, p.shape, p.size
                ));
            }
        }
        Ok(m)
    }

    /// Per-example input element count.
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// Load all model manifests from an artifacts directory.
pub fn load_manifests(dir: &str) -> Result<Vec<ModelManifest>, String> {
    let dir_path = Path::new(dir);
    let text = std::fs::read_to_string(dir_path.join("manifest.json"))
        .map_err(|e| format!("read {dir}/manifest.json: {e}"))?;
    let v = Json::parse(&text).map_err(|e| e.to_string())?;
    let models = v
        .get("models")
        .as_obj()
        .ok_or("manifest.models missing")?;
    let mut out = Vec::new();
    for (name, entry) in models {
        out.push(ModelManifest::from_json(name, dir_path, entry)?);
    }
    Ok(out)
}

/// Load one model's manifest by name.
pub fn load_manifest(dir: &str, model: &str) -> Result<ModelManifest, String> {
    load_manifests(dir)?
        .into_iter()
        .find(|m| m.name == model)
        .ok_or_else(|| format!("model '{model}' not in {dir}/manifest.json"))
}

/// Read the init-params binary (f32 little-endian concat).
pub fn load_init_params(m: &ModelManifest) -> Result<Vec<f32>, String> {
    let bytes = std::fs::read(&m.init_params)
        .map_err(|e| format!("read {:?}: {e}", m.init_params))?;
    if bytes.len() != 4 * m.num_params {
        return Err(format!(
            "{:?}: {} bytes, expected {}",
            m.init_params,
            bytes.len(),
            4 * m.num_params
        ));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

    fn have_artifacts() -> bool {
        Path::new(ART).join("manifest.json").exists()
    }

    #[test]
    fn parses_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let ms = load_manifests(ART).unwrap();
        assert!(!ms.is_empty());
        let mlp = ms.iter().find(|m| m.name == "femnist_mlp").unwrap();
        assert_eq!(mlp.input_dtype, "f32");
        assert_eq!(mlp.num_classes, 62);
        assert_eq!(mlp.input_elems(), 784);
        assert!(mlp.train_hlo.exists());
        assert!(mlp.eval_hlo.exists());
    }

    #[test]
    fn init_params_round_trip() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = load_manifest(ART, "femnist_mlp").unwrap();
        let p = load_init_params(&m).unwrap();
        assert_eq!(p.len(), m.num_params);
        assert!(p.iter().all(|v| v.is_finite()));
        // weights non-zero, biases zero-initialized
        assert!(p.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn missing_model_is_error() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        assert!(load_manifest(ART, "nonexistent_model").is_err());
    }

    #[test]
    fn schema_validation_rejects_bad_sizes() {
        let bad = Json::parse(
            r#"{"kind":"mlp","train_hlo":"a","eval_hlo":"b",
                "init_params":"c","num_params":10,
                "params":[{"name":"w","shape":[2,2],"size":4}],
                "input_shape":[4],"input_dtype":"f32","num_classes":2,
                "batch_size":2,"eval_batch":2}"#,
        )
        .unwrap();
        let err = ModelManifest::from_json("bad", Path::new("."), &bad);
        assert!(err.is_err());
        assert!(err.unwrap_err().contains("param sizes"));
    }
}
