//! Approximate Optimal Client Sampling — Algorithm 2 of the paper.
//!
//! The exact solver (Eq. 7) needs the master to see *individual* norms
//! and partially sort them, which breaks secure aggregation. Algorithm 2
//! reaches the same fixed point using only aggregated sums:
//!
//! 1. clients send `u_i = w_i‖U_i‖`; master aggregates `u = Σ u_i` and
//!    broadcasts it;
//! 2. each client sets `p_i = min(m·u_i/u, 1)`;
//! 3. for up to `j_max` rounds: clients with `p_i < 1` send `(1, p_i)`
//!    (others `(0, 0)`); master aggregates `(I, P)`, broadcasts
//!    `C = (m − n + I)/P`; clients rescale `p_i ← min(C·p_i, 1)`;
//!    stop when `C ≤ 1`.
//!
//! Every message is a plain sum, so the whole exchange runs under the
//! [`crate::secure_agg`] protocol; clients keep no state between rounds.

/// Transport of the sharded negotiation
/// ([`aocs_probabilities_sharded`]): handed per-shard `(client id,
/// scalar)` pairs, returns each shard's (securely computed) sum.
pub type ShardScalarSums = dyn FnMut(&[Vec<(u64, f32)>]) -> Vec<f32>;

/// Result of one AOCS probability negotiation.
#[derive(Clone, Debug)]
pub struct AocsResult {
    /// Final inclusion probabilities (client order preserved).
    pub probs: Vec<f64>,
    /// Number of rescaling iterations actually executed (≤ j_max).
    pub iterations: usize,
    /// True iff the loop exited via the `C ≤ 1` fixed-point test.
    pub converged: bool,
    /// Extra uplink floats *per client* spent on the negotiation
    /// (Remark 3): 1 norm + 2 per iteration.
    pub extra_uplink_floats_per_client: usize,
    /// Extra broadcast floats (u, then C per iteration) — not counted in
    /// the paper's uplink-bits metric (footnote 5) but tracked anyway.
    pub extra_downlink_floats: usize,
}

/// Run Algorithm 2 over the (already securely aggregated) norms.
///
/// This free function computes what the distributed exchange converges
/// to; [`crate::fl`] drives the same arithmetic through the actual
/// masked-aggregation message flow.
pub fn aocs_probabilities(norms: &[f64], m: usize, j_max: usize) -> AocsResult {
    let n = norms.len();
    assert!(m >= 1 && m <= n, "budget m={m} out of range for n={n}");
    let u: f64 = norms.iter().sum();

    let mut probs: Vec<f64> = if u <= 0.0 {
        vec![m as f64 / n as f64; n]
    } else {
        norms.iter().map(|&ui| (m as f64 * ui / u).min(1.0)).collect()
    };

    let mut iterations = 0;
    let mut converged = u <= 0.0; // degenerate input needs no rescaling
    for _ in 0..j_max {
        if converged {
            break;
        }
        iterations += 1;
        // master-side aggregate of t_i = (1[p_i<1], p_i·1[p_i<1])
        let mut count_open = 0usize; // I^k
        let mut mass_open = 0.0f64; // P^k
        for &p in &probs {
            if p < 1.0 {
                count_open += 1;
                mass_open += p;
            }
        }
        if count_open == 0 || mass_open <= 0.0 {
            // all clients capped (m = n) or all open probs are zero —
            // nothing left to rescale
            converged = true;
            break;
        }
        let c = (m as f64 - n as f64 + count_open as f64) / mass_open;
        if c > 1.0 {
            for p in probs.iter_mut() {
                if *p < 1.0 {
                    *p = (c * *p).min(1.0);
                }
            }
        } else {
            converged = true;
        }
    }

    AocsResult {
        probs,
        iterations,
        converged,
        extra_uplink_floats_per_client: 1 + 2 * iterations,
        extra_downlink_floats: 1 + iterations,
    }
}

/// Distributed Algorithm 2: the same fixed point as
/// [`aocs_probabilities`], negotiated through **per-shard partial sums**
/// instead of a central scan — the form that scales the negotiation with
/// the coordinator at large cohorts.
///
/// `groups[s]` lists shard `s`'s cohort members as
/// `(client id, cohort position)` pairs; `shard_sums` is the transport:
/// handed one scalar per member grouped by shard, it returns each
/// shard's sum. The coordinator routes it through
/// `LocalRunner::negotiation_partials`, i.e. secure masked folds fanned
/// over the shard worker pool, so the master combines only O(shards)
/// scalars per aggregate — u in the first exchange, (I, P) per
/// rescaling iteration — and never observes an individual client's
/// value (the property Algorithm 2 exists to preserve).
///
/// Numerics: partial sums travel as f32 through the fixed-point
/// secure-aggregation ring, so the result can differ from the central
/// f64 solver in the last ulps; the fixed point itself is identical
/// (property-pinned: converged runs satisfy Σp ≈ m and preserve the
/// open-client proportionality p_i/p_j = ũ_i/ũ_j). Use the central path
/// when bitwise trajectory compatibility with the seed protocol matters.
pub fn aocs_probabilities_sharded(
    norms: &[f64],
    groups: &[Vec<(u64, usize)>],
    m: usize,
    j_max: usize,
    shard_sums: &mut ShardScalarSums,
) -> AocsResult {
    let n = norms.len();
    assert!(m >= 1 && m <= n, "budget m={m} out of range for n={n}");
    debug_assert_eq!(
        groups.iter().map(Vec::len).sum::<usize>(),
        n,
        "groups must partition the cohort"
    );

    // stage a per-member scalar, grouped by shard
    let stage = |f: &dyn Fn(usize) -> f32| -> Vec<Vec<(u64, f32)>> {
        groups
            .iter()
            .map(|g| g.iter().map(|&(id, p)| (id, f(p))).collect())
            .collect()
    };
    let combine = |partials: Vec<f32>| -> f64 {
        partials.into_iter().map(f64::from).sum()
    };

    // exchange 1: u = Σ ũ_i as per-shard sums
    let u = combine(shard_sums(&stage(&|p| norms[p] as f32)));
    if u <= 0.0 {
        // degenerate norms: uniform fallback, nothing to rescale
        return AocsResult {
            probs: vec![m as f64 / n as f64; n],
            iterations: 0,
            converged: true,
            extra_uplink_floats_per_client: 1,
            extra_downlink_floats: 1,
        };
    }

    // clients initialize locally from the broadcast u
    let mut probs: Vec<f64> =
        norms.iter().map(|&ui| (m as f64 * ui / u).min(1.0)).collect();

    let mut iterations = 0;
    let mut converged = false;
    for _ in 0..j_max {
        if converged {
            break;
        }
        iterations += 1;
        // exchange j: (I, P) over the still-uncapped clients
        let count_open = combine(shard_sums(&stage(&|p| {
            if probs[p] < 1.0 {
                1.0
            } else {
                0.0
            }
        })))
        .round() as usize;
        let mass_open = combine(shard_sums(&stage(&|p| {
            if probs[p] < 1.0 {
                probs[p] as f32
            } else {
                0.0
            }
        })));
        if count_open == 0 || mass_open <= 0.0 {
            converged = true;
            break;
        }
        let c = (m as f64 - n as f64 + count_open as f64) / mass_open;
        if c > 1.0 {
            for p in probs.iter_mut() {
                if *p < 1.0 {
                    *p = (c * *p).min(1.0);
                }
            }
        } else {
            converged = true;
        }
    }

    AocsResult {
        probs,
        iterations,
        converged,
        extra_uplink_floats_per_client: 1 + 2 * iterations,
        extra_downlink_floats: 1 + iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::ocs::ocs_probabilities;
    use crate::util::prop::{norm_profile, quick};

    #[test]
    fn no_caps_means_single_iteration() {
        // norms proportional enough that min() never truncates
        let r = aocs_probabilities(&[1.0, 1.0, 1.0, 1.0], 2, 4);
        assert!(r.converged);
        assert_eq!(r.iterations, 1); // first check sees C = 1 and stops
        for &p in &r.probs {
            assert!((p - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_exact_on_capped_profile() {
        let norms = [100.0, 1.0, 1.0];
        let r = aocs_probabilities(&norms, 2, 4);
        let exact = ocs_probabilities(&norms, 2).probs;
        for (a, b) in r.probs.iter().zip(&exact) {
            assert!((a - b).abs() < 1e-9, "{:?} vs {exact:?}", r.probs);
        }
        assert!(r.converged);
    }

    #[test]
    fn zero_norms_uniform_fallback() {
        let r = aocs_probabilities(&[0.0; 5], 2, 4);
        for &p in &r.probs {
            assert!((p - 0.4).abs() < 1e-12);
        }
        assert_eq!(r.iterations, 0);
    }

    #[test]
    fn j_max_zero_skips_rescaling() {
        let norms = [100.0, 1.0, 1.0];
        let r = aocs_probabilities(&norms, 2, 0);
        assert_eq!(r.iterations, 0);
        assert!(!r.converged);
        // initial truncation only: Σp < m (the gap Alg. 2 exists to fix)
        let b: f64 = r.probs.iter().sum();
        assert!(b < 2.0);
    }

    #[test]
    fn communication_accounting_matches_remark3() {
        let norms = [100.0, 50.0, 1.0, 1.0, 1.0, 1.0];
        let r = aocs_probabilities(&norms, 3, 4);
        assert_eq!(r.extra_uplink_floats_per_client, 1 + 2 * r.iterations);
        assert_eq!(r.extra_downlink_floats, 1 + r.iterations);
        assert!(r.extra_uplink_floats_per_client <= 1 + 2 * 4);
    }

    #[test]
    fn prop_valid_probabilities_and_budget() {
        quick("aocs-valid", |rng, _| {
            let n = rng.range(1, 80);
            let m = rng.range(1, n + 1);
            let norms = norm_profile(rng, n);
            let r = aocs_probabilities(&norms, m, 4);
            for &p in &r.probs {
                if !(0.0..=1.0 + 1e-12).contains(&p) {
                    return Err(format!("p={p}"));
                }
            }
            let b: f64 = r.probs.iter().sum();
            if b > m as f64 + 1e-6 {
                return Err(format!("Σp={b} > m={m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_converges_to_exact_ocs() {
        // §5.1 footnote 4: Algorithms 1 and 2 give identical results.
        // Each rescaling round either caps a new client or reaches the
        // fixed point, so j_max = n + 2 guarantees full convergence.
        quick("aocs-eq-ocs", |rng, _| {
            let n = rng.range(2, 64);
            let m = rng.range(1, n + 1);
            let norms: Vec<f64> =
                (0..n).map(|_| rng.exponential(0.3) + 1e-3).collect();
            let approx = aocs_probabilities(&norms, m, n + 2);
            let exact = ocs_probabilities(&norms, m).probs;
            for (i, (a, b)) in approx.probs.iter().zip(&exact).enumerate() {
                if (a - b).abs() > 1e-6 {
                    return Err(format!(
                        "client {i}: aocs={a} ocs={b} (n={n} m={m})"
                    ));
                }
            }
            Ok(())
        });
    }

    /// Round-robin shard grouping of cohort positions 0..n.
    fn round_robin_groups(n: usize, shards: usize) -> Vec<Vec<(u64, usize)>> {
        let mut groups = vec![Vec::new(); shards];
        for p in 0..n {
            groups[p % shards].push((100 + p as u64, p));
        }
        groups
    }

    /// Plain (unmasked) f32 shard sums — isolates the algorithm from the
    /// secure transport.
    fn plain_sums(gs: &[Vec<(u64, f32)>]) -> Vec<f32> {
        gs.iter().map(|g| g.iter().map(|&(_, x)| x).sum()).collect()
    }

    #[test]
    fn sharded_matches_central_on_separated_profiles() {
        // profiles where f32 transport noise cannot flip a cap decision
        for (norms, m) in [
            (vec![100.0, 1.0, 1.0], 2usize),
            (vec![8.0, 4.0, 2.0, 1.0, 1.0, 1.0], 3),
            (vec![1.0; 8], 4),
        ] {
            let central = aocs_probabilities(&norms, m, 6);
            for shards in [1, 2, 3] {
                let groups = round_robin_groups(norms.len(), shards);
                let sharded = aocs_probabilities_sharded(
                    &norms,
                    &groups,
                    m,
                    6,
                    &mut plain_sums,
                );
                // iteration counts may differ by a no-op rescale when c
                // sits on the 1.0 boundary; the probabilities may not
                for (a, b) in sharded.probs.iter().zip(&central.probs) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "shards={shards}: {:?} vs {:?}",
                        sharded.probs,
                        central.probs
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_zero_norms_fall_back_to_uniform() {
        let groups = round_robin_groups(5, 2);
        let r = aocs_probabilities_sharded(
            &[0.0; 5],
            &groups,
            2,
            4,
            &mut plain_sums,
        );
        for &p in &r.probs {
            assert!((p - 0.4).abs() < 1e-12);
        }
        assert_eq!(r.iterations, 0);
        assert!(r.converged);
    }

    #[test]
    fn prop_sharded_negotiation_reaches_the_same_fixed_point() {
        // invariants robust to f32 transport noise: probabilities valid,
        // budget respected, converged runs hit Σp ≈ m, and open clients
        // keep the proportionality p_i/p_j = ũ_i/ũ_j
        quick("aocs-sharded-fixed-point", |rng, _| {
            let n = rng.range(2, 64);
            let m = rng.range(1, n + 1);
            let norms: Vec<f64> =
                (0..n).map(|_| rng.exponential(0.3) + 1e-3).collect();
            let shards = rng.range(1, 7);
            let groups = round_robin_groups(n, shards);
            let r = aocs_probabilities_sharded(
                &norms,
                &groups,
                m,
                n + 2,
                &mut plain_sums,
            );
            let total: f64 = r.probs.iter().sum();
            for &p in &r.probs {
                if !(0.0..=1.0 + 1e-9).contains(&p) {
                    return Err(format!("p={p}"));
                }
            }
            if total > m as f64 + 1e-3 {
                return Err(format!("Σp={total} > m={m}"));
            }
            if r.converged && (total - m as f64).abs() > 0.02 {
                return Err(format!("converged but Σp={total} != m={m}"));
            }
            for i in 0..n {
                for j in (i + 1)..n {
                    if r.probs[i] < 1.0 && r.probs[j] < 1.0 {
                        let lhs = r.probs[i] * norms[j];
                        let rhs = r.probs[j] * norms[i];
                        let scale = lhs.abs().max(rhs.abs()).max(1e-12);
                        if (lhs - rhs).abs() / scale > 1e-6 {
                            return Err(format!(
                                "open pair ({i},{j}) broke proportionality"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sharded_secure_transport_tracks_plain_sums() {
        // the real transport: per-shard masked folds through the
        // fixed-point ring (what LocalRunner::negotiation_partials runs)
        use crate::secure_agg::SecureAggregator;
        let norms = vec![5.0, 3.0, 2.0, 1.0, 0.5, 0.25, 4.0, 0.75];
        let m = 3;
        let groups = round_robin_groups(norms.len(), 3);
        let agg = SecureAggregator::new(0xA0C5);
        let mut secure_sums = |gs: &[Vec<(u64, f32)>]| -> Vec<f32> {
            gs.iter().map(|g| agg.aggregate_scalars(g)).collect()
        };
        let secure = aocs_probabilities_sharded(
            &norms,
            &groups,
            m,
            6,
            &mut secure_sums,
        );
        let plain = aocs_probabilities_sharded(
            &norms,
            &groups,
            m,
            6,
            &mut plain_sums,
        );
        for (a, b) in secure.probs.iter().zip(&plain.probs) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        let total: f64 = secure.probs.iter().sum();
        assert!(total <= m as f64 + 1e-3, "Σp={total}");
    }

    #[test]
    fn prop_monotone_under_iterations() {
        // more rescaling iterations only move Σp upward toward m
        quick("aocs-monotone-budget", |rng, _| {
            let n = rng.range(2, 40);
            let m = rng.range(1, n + 1);
            let norms = norm_profile(rng, n);
            let mut last = -1.0;
            for j in 0..5 {
                let b: f64 =
                    aocs_probabilities(&norms, m, j).probs.iter().sum();
                if b + 1e-9 < last {
                    return Err(format!("budget shrank at j={j}: {b} < {last}"));
                }
                last = b;
            }
            Ok(())
        });
    }
}
