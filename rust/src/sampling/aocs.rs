//! Approximate Optimal Client Sampling — Algorithm 2 of the paper.
//!
//! The exact solver (Eq. 7) needs the master to see *individual* norms
//! and partially sort them, which breaks secure aggregation. Algorithm 2
//! reaches the same fixed point using only aggregated sums:
//!
//! 1. clients send `u_i = w_i‖U_i‖`; master aggregates `u = Σ u_i` and
//!    broadcasts it;
//! 2. each client sets `p_i = min(m·u_i/u, 1)`;
//! 3. for up to `j_max` rounds: clients with `p_i < 1` send `(1, p_i)`
//!    (others `(0, 0)`); master aggregates `(I, P)`, broadcasts
//!    `C = (m − n + I)/P`; clients rescale `p_i ← min(C·p_i, 1)`;
//!    stop when `C ≤ 1`.
//!
//! Every message is a plain sum, so the whole exchange runs under the
//! [`crate::secure_agg`] protocol; clients keep no state between rounds.

/// Result of one AOCS probability negotiation.
#[derive(Clone, Debug)]
pub struct AocsResult {
    /// Final inclusion probabilities (client order preserved).
    pub probs: Vec<f64>,
    /// Number of rescaling iterations actually executed (≤ j_max).
    pub iterations: usize,
    /// True iff the loop exited via the `C ≤ 1` fixed-point test.
    pub converged: bool,
    /// Extra uplink floats *per client* spent on the negotiation
    /// (Remark 3): 1 norm + 2 per iteration.
    pub extra_uplink_floats_per_client: usize,
    /// Extra broadcast floats (u, then C per iteration) — not counted in
    /// the paper's uplink-bits metric (footnote 5) but tracked anyway.
    pub extra_downlink_floats: usize,
}

/// Run Algorithm 2 over the (already securely aggregated) norms.
///
/// This free function computes what the distributed exchange converges
/// to; [`crate::fl`] drives the same arithmetic through the actual
/// masked-aggregation message flow.
pub fn aocs_probabilities(norms: &[f64], m: usize, j_max: usize) -> AocsResult {
    let n = norms.len();
    assert!(m >= 1 && m <= n, "budget m={m} out of range for n={n}");
    let u: f64 = norms.iter().sum();

    let mut probs: Vec<f64> = if u <= 0.0 {
        vec![m as f64 / n as f64; n]
    } else {
        norms.iter().map(|&ui| (m as f64 * ui / u).min(1.0)).collect()
    };

    let mut iterations = 0;
    let mut converged = u <= 0.0; // degenerate input needs no rescaling
    for _ in 0..j_max {
        if converged {
            break;
        }
        iterations += 1;
        // master-side aggregate of t_i = (1[p_i<1], p_i·1[p_i<1])
        let mut count_open = 0usize; // I^k
        let mut mass_open = 0.0f64; // P^k
        for &p in &probs {
            if p < 1.0 {
                count_open += 1;
                mass_open += p;
            }
        }
        if count_open == 0 || mass_open <= 0.0 {
            // all clients capped (m = n) or all open probs are zero —
            // nothing left to rescale
            converged = true;
            break;
        }
        let c = (m as f64 - n as f64 + count_open as f64) / mass_open;
        if c > 1.0 {
            for p in probs.iter_mut() {
                if *p < 1.0 {
                    *p = (c * *p).min(1.0);
                }
            }
        } else {
            converged = true;
        }
    }

    AocsResult {
        probs,
        iterations,
        converged,
        extra_uplink_floats_per_client: 1 + 2 * iterations,
        extra_downlink_floats: 1 + iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::ocs::ocs_probabilities;
    use crate::util::prop::{norm_profile, quick};

    #[test]
    fn no_caps_means_single_iteration() {
        // norms proportional enough that min() never truncates
        let r = aocs_probabilities(&[1.0, 1.0, 1.0, 1.0], 2, 4);
        assert!(r.converged);
        assert_eq!(r.iterations, 1); // first check sees C = 1 and stops
        for &p in &r.probs {
            assert!((p - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_exact_on_capped_profile() {
        let norms = [100.0, 1.0, 1.0];
        let r = aocs_probabilities(&norms, 2, 4);
        let exact = ocs_probabilities(&norms, 2).probs;
        for (a, b) in r.probs.iter().zip(&exact) {
            assert!((a - b).abs() < 1e-9, "{:?} vs {exact:?}", r.probs);
        }
        assert!(r.converged);
    }

    #[test]
    fn zero_norms_uniform_fallback() {
        let r = aocs_probabilities(&[0.0; 5], 2, 4);
        for &p in &r.probs {
            assert!((p - 0.4).abs() < 1e-12);
        }
        assert_eq!(r.iterations, 0);
    }

    #[test]
    fn j_max_zero_skips_rescaling() {
        let norms = [100.0, 1.0, 1.0];
        let r = aocs_probabilities(&norms, 2, 0);
        assert_eq!(r.iterations, 0);
        assert!(!r.converged);
        // initial truncation only: Σp < m (the gap Alg. 2 exists to fix)
        let b: f64 = r.probs.iter().sum();
        assert!(b < 2.0);
    }

    #[test]
    fn communication_accounting_matches_remark3() {
        let norms = [100.0, 50.0, 1.0, 1.0, 1.0, 1.0];
        let r = aocs_probabilities(&norms, 3, 4);
        assert_eq!(r.extra_uplink_floats_per_client, 1 + 2 * r.iterations);
        assert_eq!(r.extra_downlink_floats, 1 + r.iterations);
        assert!(r.extra_uplink_floats_per_client <= 1 + 2 * 4);
    }

    #[test]
    fn prop_valid_probabilities_and_budget() {
        quick("aocs-valid", |rng, _| {
            let n = rng.range(1, 80);
            let m = rng.range(1, n + 1);
            let norms = norm_profile(rng, n);
            let r = aocs_probabilities(&norms, m, 4);
            for &p in &r.probs {
                if !(0.0..=1.0 + 1e-12).contains(&p) {
                    return Err(format!("p={p}"));
                }
            }
            let b: f64 = r.probs.iter().sum();
            if b > m as f64 + 1e-6 {
                return Err(format!("Σp={b} > m={m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_converges_to_exact_ocs() {
        // §5.1 footnote 4: Algorithms 1 and 2 give identical results.
        // Each rescaling round either caps a new client or reaches the
        // fixed point, so j_max = n + 2 guarantees full convergence.
        quick("aocs-eq-ocs", |rng, _| {
            let n = rng.range(2, 64);
            let m = rng.range(1, n + 1);
            let norms: Vec<f64> =
                (0..n).map(|_| rng.exponential(0.3) + 1e-3).collect();
            let approx = aocs_probabilities(&norms, m, n + 2);
            let exact = ocs_probabilities(&norms, m).probs;
            for (i, (a, b)) in approx.probs.iter().zip(&exact).enumerate() {
                if (a - b).abs() > 1e-6 {
                    return Err(format!(
                        "client {i}: aocs={a} ocs={b} (n={n} m={m})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_under_iterations() {
        // more rescaling iterations only move Σp upward toward m
        quick("aocs-monotone-budget", |rng, _| {
            let n = rng.range(2, 40);
            let m = rng.range(1, n + 1);
            let norms = norm_profile(rng, n);
            let mut last = -1.0;
            for j in 0..5 {
                let b: f64 =
                    aocs_probabilities(&norms, m, j).probs.iter().sum();
                if b + 1e-9 < last {
                    return Err(format!("budget shrank at j={j}: {b} < {last}"));
                }
                last = b;
            }
            Ok(())
        });
    }
}
