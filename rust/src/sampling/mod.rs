//! Client sampling schemes — the paper's contribution (Section 2).
//!
//! [`Sampler`] unifies the strategy zoo compared in the evaluation:
//! full participation, independent uniform sampling, exact OCS
//! (Algorithm 1 / Eq. 7), approximate OCS (Algorithm 2), and three
//! DESIGN.md §13 extensions — [`clustered`] stratified draws over
//! norm-history clusters, [`cyclic`] regularized group participation,
//! and compression-aware AOCS (`caocs`, the Algorithm-2 solver fed the
//! *compressed* payload norms `w_i‖C(U_i^k)‖`). All of them consume
//! per-round weighted update norms and produce inclusion probabilities
//! for an independent sampling.
//!
//! The supporting modules: [`ocs`] solves Eq. (7) exactly, [`aocs`]
//! reaches the same fixed point through sum-only exchanges (including
//! the sharded form [`aocs::aocs_probabilities_sharded`], which
//! negotiates over per-shard secure partial sums), [`probability`]
//! draws the independent transmission set, and [`variance`] computes
//! the α/γ diagnostics (Definitions 11–12).
//!
//! ```
//! use fedsamp::sampling::Sampler;
//! let norms = vec![5.0, 1.0, 1.0, 1.0]; // ũ_i = w_i‖U_i‖
//! let d = Sampler::Ocs.decide(&norms, 2); // expected budget m = 2
//! let expected: f64 = d.probs.iter().sum();
//! assert!((expected - 2.0).abs() < 1e-6);
//! assert!(d.probs[0] >= d.probs[1]); // larger norms, larger p_i
//! ```

pub mod aocs;
pub mod clustered;
pub mod cyclic;
pub mod ocs;
pub mod probability;
pub mod variance;

use crate::config::Strategy;
use clustered::NormHistory;
use std::cell::RefCell;

/// Per-round sampling decision handed to the FL round driver.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Inclusion probability per cohort client.
    pub probs: Vec<f64>,
    /// Extra uplink floats per client spent negotiating probabilities
    /// (0 for full/uniform/exact-OCS*, 1 + 2·iters for AOCS — Remark 3).
    ///
    /// *exact OCS still uploads one norm float per client (Algorithm 1
    /// line 3); that is accounted here too.
    pub extra_uplink_floats_per_client: usize,
    /// Extra synchronous communication rounds used by the negotiation.
    pub negotiation_rounds: usize,
}

impl Decision {
    /// Decision from an AOCS negotiation outcome — the single site of
    /// the Remark-3 accounting mapping, shared by the central
    /// [`Sampler::decide`] arm and the coordinator's sharded-negotiation
    /// path so the two can never drift apart.
    pub fn from_aocs(r: aocs::AocsResult) -> Decision {
        Decision {
            extra_uplink_floats_per_client: r.extra_uplink_floats_per_client,
            negotiation_rounds: 1 + r.iterations,
            probs: r.probs,
        }
    }
}

/// Strategy dispatcher.
#[derive(Clone, Debug, PartialEq)]
pub enum Sampler {
    Full,
    Uniform,
    Ocs,
    Aocs {
        j_max: usize,
    },
    /// Compression-aware AOCS: the same Algorithm-2 solver, fed the
    /// norms of the *compressed* payloads the clients would actually
    /// transmit (the coordinator resolves those norms; the sampler
    /// math is identical to [`Sampler::Aocs`]).
    Caocs {
        j_max: usize,
    },
    /// Stratified draw over norm-history clusters. The EWMA history is
    /// interior state behind a [`RefCell`] so observing a round's
    /// norms stays compatible with the `&self` decide surface.
    Clustered {
        k: usize,
        history: RefCell<NormHistory>,
    },
    /// Regularized cyclic participation: the coordinator restricts the
    /// cohort to the scheduled group at Announce; within the group the
    /// draw is uniform.
    Cyclic {
        g: usize,
    },
}

impl Sampler {
    pub fn from_strategy(s: &Strategy) -> Sampler {
        match s {
            Strategy::Full => Sampler::Full,
            Strategy::Uniform => Sampler::Uniform,
            Strategy::Ocs => Sampler::Ocs,
            Strategy::Aocs { j_max } => Sampler::Aocs { j_max: *j_max },
            Strategy::Caocs { j_max } => Sampler::Caocs { j_max: *j_max },
            Strategy::Clustered { k } => Sampler::Clustered {
                k: *k,
                history: RefCell::new(NormHistory::new()),
            },
            Strategy::Cyclic { g } => Sampler::Cyclic { g: *g },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Sampler::Full => "full",
            Sampler::Uniform => "uniform",
            Sampler::Ocs => "ocs",
            Sampler::Aocs { .. } => "aocs",
            Sampler::Caocs { .. } => "caocs",
            Sampler::Clustered { .. } => "clustered",
            Sampler::Cyclic { .. } => "cyclic",
        }
    }

    /// Compute this round's inclusion probabilities.
    ///
    /// `norms[i] = w_i‖U_i^k‖` (weighted); `m` = expected budget.
    pub fn decide(&self, norms: &[f64], m: usize) -> Decision {
        let n = norms.len();
        assert!(n > 0, "empty cohort");
        match self {
            Sampler::Full => Decision {
                probs: vec![1.0; n],
                extra_uplink_floats_per_client: 0,
                negotiation_rounds: 0,
            },
            Sampler::Uniform => Decision {
                probs: vec![(m as f64 / n as f64).min(1.0); n],
                extra_uplink_floats_per_client: 0,
                negotiation_rounds: 0,
            },
            Sampler::Ocs => {
                let r = ocs::ocs_probabilities(norms, m.min(n));
                Decision {
                    probs: r.probs,
                    // Algorithm 1 line 3: one norm float per client
                    extra_uplink_floats_per_client: 1,
                    negotiation_rounds: 1,
                }
            }
            Sampler::Aocs { j_max } => Decision::from_aocs(
                aocs::aocs_probabilities(norms, m.min(n), *j_max),
            ),
            // caocs is AOCS over whatever norms the caller supplies;
            // the coordinator substitutes compressed-payload norms
            // (with no compressor configured the two coincide), and
            // the Remark-3 accounting is identical
            Sampler::Caocs { j_max } => Decision::from_aocs(
                aocs::aocs_probabilities(norms, m.min(n), *j_max),
            ),
            // within the scheduled group (the cohort the coordinator
            // retained at Announce) cyclic draws uniformly — the m/n
            // budget contract, with full group participation back
            // whenever m covers the group
            Sampler::Cyclic { .. } => Decision {
                probs: vec![(m as f64 / n as f64).min(1.0); n],
                extra_uplink_floats_per_client: 0,
                negotiation_rounds: 0,
            },
            // without cohort ids (theory-tool path), treat positions
            // as ids — decide_for_round carries the real ids
            Sampler::Clustered { .. } => {
                let ids: Vec<usize> = (0..n).collect();
                self.decide_for_round(&ids, norms, m)
            }
        }
    }

    /// [`Sampler::decide`] with the cohort's global client ids in
    /// scope — the entry point the coordinator uses. Only the
    /// clustered strategy needs the ids (its norm history and virtual
    /// shard seeding are keyed by client, not cohort position); every
    /// other strategy falls through to [`Sampler::decide`].
    pub fn decide_for_round(
        &self,
        cohort: &[usize],
        norms: &[f64],
        m: usize,
    ) -> Decision {
        match self {
            Sampler::Clustered { k, history } => {
                let n = norms.len();
                assert!(n > 0, "empty cohort");
                assert_eq!(cohort.len(), n, "cohort/norm arity mismatch");
                let features: Vec<f64> = {
                    let mut h = history.borrow_mut();
                    cohort
                        .iter()
                        .zip(norms)
                        .map(|(&c, &u)| h.observe(c, u))
                        .collect()
                };
                let plan = clustered::clustered_probabilities(
                    cohort,
                    &features,
                    norms,
                    *k,
                    m.min(n),
                );
                Decision {
                    probs: plan.probs,
                    // like exact OCS: one norm float uplinked per
                    // client, one negotiation round to return probs
                    extra_uplink_floats_per_client: 1,
                    negotiation_rounds: 1,
                }
            }
            _ => self.decide(norms, m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::variance::{sampling_variance, uniform_variance};
    use crate::util::prop::{norm_profile, quick};

    #[test]
    fn full_and_uniform_ignore_norms() {
        let norms = [9.0, 1.0, 4.0, 2.0];
        let f = Sampler::Full.decide(&norms, 2);
        assert_eq!(f.probs, vec![1.0; 4]);
        let u = Sampler::Uniform.decide(&norms, 2);
        assert_eq!(u.probs, vec![0.5; 4]);
        assert_eq!(u.extra_uplink_floats_per_client, 0);
    }

    #[test]
    fn from_strategy_round_trips() {
        for s in [
            Strategy::Full,
            Strategy::Uniform,
            Strategy::Ocs,
            Strategy::Aocs { j_max: 4 },
            Strategy::Caocs { j_max: 4 },
            Strategy::Clustered { k: 3 },
            Strategy::Cyclic { g: 2 },
        ] {
            let smp = Sampler::from_strategy(&s);
            assert_eq!(smp.name(), s.name());
        }
    }

    #[test]
    fn caocs_matches_aocs_on_identical_norms() {
        // the solver is shared; only the coordinator's norm source
        // differs, so on equal inputs the decisions are bitwise equal
        let norms = [3.0, 1.0, 0.5, 2.0, 0.0, 4.0];
        let a = Sampler::Aocs { j_max: 4 }.decide(&norms, 3);
        let c = Sampler::Caocs { j_max: 4 }.decide(&norms, 3);
        assert_eq!(a.probs, c.probs);
        assert_eq!(
            a.extra_uplink_floats_per_client,
            c.extra_uplink_floats_per_client
        );
        assert_eq!(a.negotiation_rounds, c.negotiation_rounds);
    }

    #[test]
    fn cyclic_draws_uniform_within_the_scheduled_group() {
        let norms = [9.0, 1.0, 4.0, 2.0];
        let d = Sampler::Cyclic { g: 3 }.decide(&norms, 2);
        assert_eq!(d.probs, vec![0.5; 4]);
        assert_eq!(d.extra_uplink_floats_per_client, 0);
        assert_eq!(d.negotiation_rounds, 0);
        // budget beyond the group size → everyone in the group runs
        let full = Sampler::Cyclic { g: 3 }.decide(&norms, 9);
        assert_eq!(full.probs, vec![1.0; 4]);
    }

    #[test]
    fn clustered_decides_through_ids_and_charges_like_ocs() {
        let smp = Sampler::from_strategy(&Strategy::Clustered { k: 2 });
        let cohort = [10usize, 11, 12, 13];
        let norms = [0.1, 0.1, 5.0, 5.0];
        let d = smp.decide_for_round(&cohort, &norms, 2);
        assert_eq!(d.probs.len(), 4);
        assert_eq!(d.extra_uplink_floats_per_client, 1);
        assert_eq!(d.negotiation_rounds, 1);
        // heavy band gets at least the light band's probability
        assert!(d.probs[2] >= d.probs[0]);
        // id-less path is the identity-cohort special case
        let d2 = Sampler::from_strategy(&Strategy::Clustered { k: 2 })
            .decide(&norms, 2);
        assert_eq!(d2.probs.len(), 4);
    }

    #[test]
    fn non_clustered_decide_for_round_ignores_ids() {
        let norms = [5.0, 1.0, 1.0, 1.0];
        let cohort = [40usize, 2, 17, 33];
        for smp in [
            Sampler::Full,
            Sampler::Uniform,
            Sampler::Ocs,
            Sampler::Aocs { j_max: 4 },
            Sampler::Caocs { j_max: 4 },
            Sampler::Cyclic { g: 2 },
        ] {
            let a = smp.decide_for_round(&cohort, &norms, 2);
            let b = smp.decide(&norms, 2);
            assert_eq!(a.probs, b.probs, "{}", smp.name());
        }
    }

    #[test]
    fn ocs_charges_norm_float() {
        let d = Sampler::Ocs.decide(&[1.0, 2.0], 1);
        assert_eq!(d.extra_uplink_floats_per_client, 1);
    }

    #[test]
    fn prop_strategy_variance_ordering() {
        // Var(full)=0 ≤ Var(OCS) ≤ Var(AOCS(j_max=4)) ≲ Var(uniform)
        quick("variance-order", |rng, _| {
            let n = rng.range(2, 48);
            let m = rng.range(1, n);
            let norms = norm_profile(rng, n);
            if norms.iter().sum::<f64>() <= 0.0 {
                return Ok(());
            }
            let v_full =
                sampling_variance(&norms, &Sampler::Full.decide(&norms, m).probs);
            let v_ocs =
                sampling_variance(&norms, &Sampler::Ocs.decide(&norms, m).probs);
            let v_aocs = sampling_variance(
                &norms,
                &Sampler::Aocs { j_max: 4 }.decide(&norms, m).probs,
            );
            let v_uni = uniform_variance(&norms, m);
            if v_full != 0.0 {
                return Err("full variance not zero".into());
            }
            if v_ocs > v_uni * (1.0 + 1e-9) + 1e-12 {
                return Err(format!("ocs {v_ocs} > uniform {v_uni}"));
            }
            if v_ocs > v_aocs * (1.0 + 1e-9) + 1e-12 {
                return Err(format!("ocs {v_ocs} > aocs {v_aocs}"));
            }
            Ok(())
        });
    }
}
