//! Client sampling schemes — the paper's contribution (Section 2).
//!
//! [`Sampler`] unifies the four strategies compared in the evaluation:
//! full participation, independent uniform sampling, exact OCS
//! (Algorithm 1 / Eq. 7) and approximate OCS (Algorithm 2). All of them
//! consume the per-round weighted update norms `ũ_i = w_i‖U_i^k‖` and
//! produce inclusion probabilities for an independent sampling.
//!
//! The supporting modules: [`ocs`] solves Eq. (7) exactly, [`aocs`]
//! reaches the same fixed point through sum-only exchanges (including
//! the sharded form [`aocs::aocs_probabilities_sharded`], which
//! negotiates over per-shard secure partial sums), [`probability`]
//! draws the independent transmission set, and [`variance`] computes
//! the α/γ diagnostics (Definitions 11–12).
//!
//! ```
//! use fedsamp::sampling::Sampler;
//! let norms = vec![5.0, 1.0, 1.0, 1.0]; // ũ_i = w_i‖U_i‖
//! let d = Sampler::Ocs.decide(&norms, 2); // expected budget m = 2
//! let expected: f64 = d.probs.iter().sum();
//! assert!((expected - 2.0).abs() < 1e-6);
//! assert!(d.probs[0] >= d.probs[1]); // larger norms, larger p_i
//! ```

pub mod aocs;
pub mod ocs;
pub mod probability;
pub mod variance;

use crate::config::Strategy;

/// Per-round sampling decision handed to the FL round driver.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Inclusion probability per cohort client.
    pub probs: Vec<f64>,
    /// Extra uplink floats per client spent negotiating probabilities
    /// (0 for full/uniform/exact-OCS*, 1 + 2·iters for AOCS — Remark 3).
    ///
    /// *exact OCS still uploads one norm float per client (Algorithm 1
    /// line 3); that is accounted here too.
    pub extra_uplink_floats_per_client: usize,
    /// Extra synchronous communication rounds used by the negotiation.
    pub negotiation_rounds: usize,
}

impl Decision {
    /// Decision from an AOCS negotiation outcome — the single site of
    /// the Remark-3 accounting mapping, shared by the central
    /// [`Sampler::decide`] arm and the coordinator's sharded-negotiation
    /// path so the two can never drift apart.
    pub fn from_aocs(r: aocs::AocsResult) -> Decision {
        Decision {
            extra_uplink_floats_per_client: r.extra_uplink_floats_per_client,
            negotiation_rounds: 1 + r.iterations,
            probs: r.probs,
        }
    }
}

/// Strategy dispatcher.
#[derive(Clone, Debug, PartialEq)]
pub enum Sampler {
    Full,
    Uniform,
    Ocs,
    Aocs { j_max: usize },
}

impl Sampler {
    pub fn from_strategy(s: &Strategy) -> Sampler {
        match s {
            Strategy::Full => Sampler::Full,
            Strategy::Uniform => Sampler::Uniform,
            Strategy::Ocs => Sampler::Ocs,
            Strategy::Aocs { j_max } => Sampler::Aocs { j_max: *j_max },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Sampler::Full => "full",
            Sampler::Uniform => "uniform",
            Sampler::Ocs => "ocs",
            Sampler::Aocs { .. } => "aocs",
        }
    }

    /// Compute this round's inclusion probabilities.
    ///
    /// `norms[i] = w_i‖U_i^k‖` (weighted); `m` = expected budget.
    pub fn decide(&self, norms: &[f64], m: usize) -> Decision {
        let n = norms.len();
        assert!(n > 0, "empty cohort");
        match self {
            Sampler::Full => Decision {
                probs: vec![1.0; n],
                extra_uplink_floats_per_client: 0,
                negotiation_rounds: 0,
            },
            Sampler::Uniform => Decision {
                probs: vec![(m as f64 / n as f64).min(1.0); n],
                extra_uplink_floats_per_client: 0,
                negotiation_rounds: 0,
            },
            Sampler::Ocs => {
                let r = ocs::ocs_probabilities(norms, m.min(n));
                Decision {
                    probs: r.probs,
                    // Algorithm 1 line 3: one norm float per client
                    extra_uplink_floats_per_client: 1,
                    negotiation_rounds: 1,
                }
            }
            Sampler::Aocs { j_max } => Decision::from_aocs(
                aocs::aocs_probabilities(norms, m.min(n), *j_max),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::variance::{sampling_variance, uniform_variance};
    use crate::util::prop::{norm_profile, quick};

    #[test]
    fn full_and_uniform_ignore_norms() {
        let norms = [9.0, 1.0, 4.0, 2.0];
        let f = Sampler::Full.decide(&norms, 2);
        assert_eq!(f.probs, vec![1.0; 4]);
        let u = Sampler::Uniform.decide(&norms, 2);
        assert_eq!(u.probs, vec![0.5; 4]);
        assert_eq!(u.extra_uplink_floats_per_client, 0);
    }

    #[test]
    fn from_strategy_round_trips() {
        for s in [
            Strategy::Full,
            Strategy::Uniform,
            Strategy::Ocs,
            Strategy::Aocs { j_max: 4 },
        ] {
            let smp = Sampler::from_strategy(&s);
            assert_eq!(smp.name(), s.name());
        }
    }

    #[test]
    fn ocs_charges_norm_float() {
        let d = Sampler::Ocs.decide(&[1.0, 2.0], 1);
        assert_eq!(d.extra_uplink_floats_per_client, 1);
    }

    #[test]
    fn prop_strategy_variance_ordering() {
        // Var(full)=0 ≤ Var(OCS) ≤ Var(AOCS(j_max=4)) ≲ Var(uniform)
        quick("variance-order", |rng, _| {
            let n = rng.range(2, 48);
            let m = rng.range(1, n);
            let norms = norm_profile(rng, n);
            if norms.iter().sum::<f64>() <= 0.0 {
                return Ok(());
            }
            let v_full =
                sampling_variance(&norms, &Sampler::Full.decide(&norms, m).probs);
            let v_ocs =
                sampling_variance(&norms, &Sampler::Ocs.decide(&norms, m).probs);
            let v_aocs = sampling_variance(
                &norms,
                &Sampler::Aocs { j_max: 4 }.decide(&norms, m).probs,
            );
            let v_uni = uniform_variance(&norms, m);
            if v_full != 0.0 {
                return Err("full variance not zero".into());
            }
            if v_ocs > v_uni * (1.0 + 1e-9) + 1e-12 {
                return Err(format!("ocs {v_ocs} > uniform {v_uni}"));
            }
            if v_ocs > v_aocs * (1.0 + 1e-9) + 1e-12 {
                return Err(format!("ocs {v_ocs} > aocs {v_aocs}"));
            }
            Ok(())
        });
    }
}
