//! Clustered client sampling (arXiv 2105.05883): low-variance cohorts
//! by stratifying the draw over clusters of similar clients.
//!
//! Clients are grouped by their **update-norm history** — an EWMA of
//! the weighted norms `ũ_i = w_i‖U_i‖` the master already observes
//! every round ([`NormHistory`], O(1) scalars per seen client) — with
//! a deterministic 1-D k-means: a fixed number of Lloyd iterations, no
//! RNG, distance ties to the lower centroid index. Centroids are
//! seeded from the **shard map**: member `i` belongs to virtual
//! round-robin shard [`round_robin_slot`]`(client_i, k)` (the
//! registry's exact ownership arithmetic over `k` *virtual* shards),
//! and centroid `j` starts at the ((2j+1)/2k)-quantile of shard `j`'s
//! feature values. Round-robin shards are representative samples of
//! the pool, so striding the quantile across shards spreads the
//! initial centroids over the feature range; using *virtual* shards —
//! not the physical shard count — is what keeps cluster trajectories
//! bitwise identical across deployment provisioning (the §13
//! determinism contract).
//!
//! The draw itself stays independent Bernoulli: cluster `c` with
//! current mass `S_c = Σ_{i∈c} ũ_i` receives quota `m·S_c/S`, spread
//! uniformly over its `n_c` members — `p_i = min(m·S_c/(S·n_c), 1)`.
//! For within-cluster-homogeneous norms this gives estimator variance
//! `S²/m − Σũ²` ≤ uniform's `(n/m)Σũ² − Σũ²` (Cauchy–Schwarz, equality
//! iff all norms equal) — the paper's representativity claim, pinned
//! statistically in `tests/strategy_properties.rs`. Zero-mass clusters
//! get `p = 0` (their members' updates are zero — the OCS convention),
//! and a zero total mass degrades to the uniform `m/n` draw.

use crate::coordinator::registry::round_robin_slot;
use std::collections::HashMap;

/// EWMA smoothing factor: weight on the *new* observation. 0.5 keeps
/// enough memory to stabilize clusters while tracking norm decay.
pub const HISTORY_DECAY: f64 = 0.5;

/// Fixed Lloyd iteration count — enough for 1-D k-means to settle on
/// the profiles a cohort produces; fixed (not convergence-tested) so
/// the work per round is deterministic and bounded.
pub const LLOYD_ITERS: usize = 8;

/// Per-client EWMA of observed weighted update norms — the clustering
/// feature. O(1) scalars per client ever seen in a cohort.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NormHistory {
    ewma: HashMap<usize, f64>,
}

impl NormHistory {
    pub fn new() -> NormHistory {
        NormHistory::default()
    }

    /// Fold this round's observed norm into `client`'s EWMA and return
    /// the updated feature value (first observation seeds the EWMA).
    pub fn observe(&mut self, client: usize, norm: f64) -> f64 {
        let f = match self.ewma.get(&client) {
            Some(&prev) => {
                prev + HISTORY_DECAY * (norm - prev)
            }
            None => norm,
        };
        self.ewma.insert(client, f);
        f
    }

    /// Clients tracked so far (test/diagnostic surface).
    pub fn len(&self) -> usize {
        self.ewma.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ewma.is_empty()
    }
}

/// One round's clustering outcome (assignments exposed for tests and
/// the §13 docs' worked examples).
#[derive(Clone, Debug)]
pub struct ClusteredPlan {
    /// Cluster index per cohort position.
    pub assignment: Vec<usize>,
    /// Final centroid per cluster (feature space).
    pub centroids: Vec<f64>,
    /// Inclusion probability per cohort position.
    pub probs: Vec<f64>,
}

/// Index of the q=(2j+1)/(2k) quantile in a sorted slice of `len`
/// elements (integer arithmetic — deterministic, no float rounding).
fn quantile_idx(len: usize, j: usize, k: usize) -> usize {
    debug_assert!(len > 0 && k > 0 && j < k);
    ((len - 1) * (2 * j + 1)) / (2 * k)
}

/// Shard-map-seeded centroids: centroid `j` = the strided quantile of
/// virtual shard `j`'s sorted feature values (whole-cohort fallback
/// when the virtual shard has no cohort member this round).
fn seed_centroids(cohort: &[usize], features: &[f64], kk: usize) -> Vec<f64> {
    let mut all: Vec<f64> = features.to_vec();
    all.sort_by(f64::total_cmp);
    let mut centroids = Vec::with_capacity(kk);
    for j in 0..kk {
        let mut shard: Vec<f64> = cohort
            .iter()
            .zip(features)
            .filter(|(&c, _)| round_robin_slot(c, kk) == j)
            .map(|(_, &f)| f)
            .collect();
        let pool = if shard.is_empty() {
            &all
        } else {
            shard.sort_by(f64::total_cmp);
            &shard
        };
        centroids.push(pool[quantile_idx(pool.len(), j, kk)]);
    }
    centroids
}

/// Nearest centroid by absolute distance, ties to the lower index —
/// the deterministic assignment rule.
fn nearest(centroids: &[f64], f: f64) -> usize {
    let mut best = 0usize;
    let mut best_d = (f - centroids[0]).abs();
    for (j, &c) in centroids.iter().enumerate().skip(1) {
        let d = (f - c).abs();
        if d < best_d {
            best = j;
            best_d = d;
        }
    }
    best
}

/// Cluster the cohort and compute this round's inclusion
/// probabilities.
///
/// * `cohort` — global client ids in cohort order (the shard-map seed
///   input).
/// * `features` — clustering feature per cohort position (the
///   [`NormHistory`] EWMAs).
/// * `norms` — this round's weighted norms `ũ_i` (the quota masses).
/// * `k` — requested cluster count (clamped to the cohort size).
/// * `m` — expected communication budget.
///
/// Pure and deterministic: same inputs, same plan, bit for bit.
pub fn clustered_probabilities(
    cohort: &[usize],
    features: &[f64],
    norms: &[f64],
    k: usize,
    m: usize,
) -> ClusteredPlan {
    let n = cohort.len();
    assert!(n > 0, "empty cohort");
    assert_eq!(features.len(), n, "feature arity mismatch");
    assert_eq!(norms.len(), n, "norm arity mismatch");
    assert!(k >= 1, "clustered needs k >= 1");
    assert!(
        norms.iter().all(|u| u.is_finite() && *u >= 0.0),
        "norms must be finite and non-negative"
    );
    let kk = k.min(n);
    let mut centroids = seed_centroids(cohort, features, kk);
    let mut assignment: Vec<usize> = vec![0; n];
    for _ in 0..LLOYD_ITERS {
        for (a, &f) in assignment.iter_mut().zip(features) {
            *a = nearest(&centroids, f);
        }
        let mut sums = vec![0.0f64; kk];
        let mut counts = vec![0usize; kk];
        for (&a, &f) in assignment.iter().zip(features) {
            sums[a] += f;
            counts[a] += 1;
        }
        for j in 0..kk {
            if counts[j] > 0 {
                // empty clusters keep their centroid (they may capture
                // members again as others move)
                centroids[j] = sums[j] / counts[j] as f64;
            }
        }
    }
    // final assignment against the settled centroids
    for (a, &f) in assignment.iter_mut().zip(features) {
        *a = nearest(&centroids, f);
    }

    // mass-proportional quotas over this round's actual norms
    let total: f64 = norms.iter().sum();
    let uniform = (m as f64 / n as f64).min(1.0);
    let probs = if total <= 0.0 {
        // no signal at all: degrade to the uniform draw
        vec![uniform; n]
    } else {
        let mut mass = vec![0.0f64; kk];
        let mut size = vec![0usize; kk];
        for (&a, &u) in assignment.iter().zip(norms) {
            mass[a] += u;
            size[a] += 1;
        }
        assignment
            .iter()
            .map(|&a| {
                if mass[a] <= 0.0 {
                    // zero-mass cluster: its members' updates are all
                    // zero, so spending budget there is pure waste
                    // (exactly OCS's p_i = m·0/S = 0 for ũ_i = 0)
                    0.0
                } else {
                    (m as f64 * mass[a] / (total * size[a] as f64)).min(1.0)
                }
            })
            .collect()
    };
    ClusteredPlan { assignment, centroids, probs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::probability::expected_size;
    use crate::sampling::variance::{sampling_variance, uniform_variance};

    /// Three well-separated norm bands assigned by id range, 24
    /// clients — the §13 worked profile.
    fn banded() -> (Vec<usize>, Vec<f64>) {
        let cohort: Vec<usize> = (0..24).collect();
        let feats: Vec<f64> = cohort
            .iter()
            .map(|&c| match c {
                0..=7 => 0.2 + 0.01 * c as f64,
                8..=15 => 2.0 + 0.01 * c as f64,
                _ => 8.0 + 0.01 * c as f64,
            })
            .collect();
        (cohort, feats)
    }

    #[test]
    fn kmeans_recovers_separated_bands() {
        let (cohort, feats) = banded();
        let plan = clustered_probabilities(&cohort, &feats, &feats, 3, 6);
        // every band lands in one cluster
        for band in [0..8usize, 8..16, 16..24] {
            let first = plan.assignment[band.start];
            for i in band {
                assert_eq!(plan.assignment[i], first, "client {i}");
            }
        }
        // and the three bands occupy three distinct clusters
        let mut reps: Vec<usize> =
            vec![plan.assignment[0], plan.assignment[8], plan.assignment[16]];
        reps.dedup();
        assert_eq!(reps.len(), 3, "{:?}", plan.assignment);
    }

    #[test]
    fn quota_probs_are_proper_and_budgeted() {
        let (cohort, feats) = banded();
        let m = 6;
        let plan = clustered_probabilities(&cohort, &feats, &feats, 3, m);
        for (&p, &u) in plan.probs.iter().zip(&feats) {
            assert!((0.0..=1.0).contains(&p));
            assert!(u <= 0.0 || p > 0.0, "positive norm must keep p > 0");
        }
        // caps only ever *reduce* the expected size below m
        assert!(expected_size(&plan.probs) <= m as f64 + 1e-9);
        assert!(expected_size(&plan.probs) > m as f64 * 0.5);
    }

    #[test]
    fn clustered_variance_beats_uniform_on_heterogeneous_bands() {
        let (cohort, feats) = banded();
        let m = 6;
        let plan = clustered_probabilities(&cohort, &feats, &feats, 3, m);
        let v_clu = sampling_variance(&feats, &plan.probs);
        let v_uni = uniform_variance(&feats, m);
        assert!(
            v_clu < v_uni,
            "clustered {v_clu} must beat uniform {v_uni} on bands"
        );
    }

    #[test]
    fn zero_mass_degrades_to_uniform() {
        let cohort: Vec<usize> = (0..8).collect();
        let zeros = vec![0.0; 8];
        let plan = clustered_probabilities(&cohort, &zeros, &zeros, 3, 4);
        assert_eq!(plan.probs, vec![0.5; 8]);
    }

    #[test]
    fn cluster_seeding_ignores_physical_shard_count() {
        // the plan is a pure function of (cohort, features, norms, k,
        // m) — no registry in sight — so two deployments of the same
        // experiment can never diverge here
        let (cohort, feats) = banded();
        let a = clustered_probabilities(&cohort, &feats, &feats, 3, 6);
        let b = clustered_probabilities(&cohort, &feats, &feats, 3, 6);
        assert_eq!(a.probs, b.probs);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn more_clusters_than_clients_is_clamped() {
        let cohort = vec![3usize, 7];
        let feats = vec![1.0, 5.0];
        let plan = clustered_probabilities(&cohort, &feats, &feats, 9, 1);
        assert_eq!(plan.centroids.len(), 2);
        assert_eq!(plan.probs.len(), 2);
    }

    #[test]
    fn history_ewma_tracks_and_seeds() {
        let mut h = NormHistory::new();
        assert_eq!(h.observe(4, 2.0), 2.0, "first observation seeds");
        let f = h.observe(4, 4.0);
        assert!((f - 3.0).abs() < 1e-12, "0.5-EWMA of 2 then 4 is 3: {f}");
        assert_eq!(h.len(), 1);
        h.observe(9, 1.0);
        assert_eq!(h.len(), 2);
    }
}
