//! Regularized cyclic participation (arXiv 2302.03662).
//!
//! The pool is partitioned into `g` fixed groups; round `r` admits
//! exactly the members of group `r mod g` into the cohort (the
//! coordinator applies the restriction at Announce, before any
//! deadline handling), so every client participates exactly once per
//! `g`-round cycle under always-on availability — the paper's
//! regularized participation schedule, which also gives the async
//! roadmap its natural pipelining unit.
//!
//! Group membership is a **pure function** of `(seed, client, g)` — a
//! splitmix64 hash, no RNG stream consumed — so it is identical across
//! shard/worker provisioning, costs O(1) per cohort member (the
//! announce filter stays O(cohort)), and never perturbs the cohort or
//! selection draws: a cyclic run differs from a uniform run only by
//! the retained cohort itself.

use crate::util::rng::splitmix64;

/// Seed-stream label for the group hash: domain-separates membership
/// from every live RNG stream (cohort, selection, straggler,
/// negotiation), mirroring the `STRAGGLER_STREAM` convention.
pub const CYCLIC_STREAM: u64 = 0x5C1C_11C6;

/// Odd multiplier decorrelating consecutive client ids before the hash
/// (splitmix64's own finalizer constant).
const CLIENT_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// The fixed group of `client` under `seed`: a pure hash, uniform over
/// `0..g` to within splitmix64's quality, stable for the life of a run.
pub fn group_of(seed: u64, client: usize, g: usize) -> usize {
    assert!(g >= 1, "cyclic needs g >= 1 groups");
    let mut state =
        seed ^ CYCLIC_STREAM ^ (client as u64).wrapping_mul(CLIENT_MIX);
    (splitmix64(&mut state) % g as u64) as usize
}

/// The group scheduled for `round` — a plain round-robin visit.
pub fn active_group(round: usize, g: usize) -> usize {
    assert!(g >= 1, "cyclic needs g >= 1 groups");
    round % g
}

/// Whether `client` is admitted into `round`'s cohort.
pub fn is_scheduled(seed: u64, client: usize, round: usize, g: usize) -> bool {
    group_of(seed, client, g) == active_group(round, g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_partition_every_client_once_per_cycle() {
        // conservation at the membership level: over one g-round cycle
        // each client is scheduled in exactly one round
        for g in [1usize, 3, 5] {
            for client in 0..200 {
                let scheduled: Vec<usize> = (0..g)
                    .filter(|&r| is_scheduled(42, client, r, g))
                    .collect();
                assert_eq!(scheduled.len(), 1, "client {client} g {g}");
                assert_eq!(scheduled[0], group_of(42, client, g));
            }
        }
    }

    #[test]
    fn membership_is_pure_and_seed_dependent() {
        assert_eq!(group_of(7, 13, 4), group_of(7, 13, 4));
        // different seeds shuffle the partition (holds for these pinned
        // values; a collision for every client would be a broken hash)
        let moved = (0..100)
            .filter(|&c| group_of(1, c, 4) != group_of(2, c, 4))
            .count();
        assert!(moved > 50, "seed barely moves the partition: {moved}");
    }

    #[test]
    fn groups_are_roughly_balanced() {
        let g = 4;
        let mut counts = vec![0usize; g];
        for c in 0..4000 {
            counts[group_of(9, c, g)] += 1;
        }
        for &n in &counts {
            // 4000 draws over 4 groups: expect 1000 ± a few σ (~30)
            assert!((800..1200).contains(&n), "{counts:?}");
        }
    }

    #[test]
    fn cycle_visits_each_group_once() {
        let g = 5;
        let visited: Vec<usize> = (0..g).map(|r| active_group(r, g)).collect();
        assert_eq!(visited, vec![0, 1, 2, 3, 4]);
        assert_eq!(active_group(g, g), 0, "cycle wraps");
    }
}
