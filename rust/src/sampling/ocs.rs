//! Exact Optimal Client Sampling — Equation (7) / Lemma 20 of the paper.
//!
//! Given weighted update norms `ũ_i = w_i‖U_i‖` and an expected budget
//! `m`, the inclusion probabilities minimizing the sampling variance
//! `Σ_i (1−p_i)/p_i · ũ_i²` subject to `Σ_i p_i ≤ m`, `0 ≤ p_i ≤ 1` are
//!
//! ```text
//! p_i = (m + l − n) · ũ_i / Σ_{j≤l} ũ_(j)    for i outside the cap set
//! p_i = 1                                      for the n − l largest ũ_i
//! ```
//!
//! where `ũ_(j)` is the j-th *smallest* norm and `l` is the largest
//! integer with `0 < m + l − n` and `(m + l − n)·ũ_(l) ≤ Σ_{j≤l} ũ_(j)`
//! (the multiplicative form is division-free and handles ũ_(l) = 0).
//!
//! Cost: O(n log n) for the sort + O(m) for the cap search (the loop
//! visits at most m values of l, since l ≥ n − m + 1 always terminates).

/// Output of the exact solver.
#[derive(Clone, Debug)]
pub struct OcsProbs {
    /// p_i aligned with the input `norms` order.
    pub probs: Vec<f64>,
    /// The threshold index l from Eq. (7) (number of non-capped clients).
    pub l: usize,
    /// Number of clients assigned p_i = 1.
    pub capped: usize,
}

/// Compute the exact optimal probabilities for one round.
///
/// `norms[i]` must be the *weighted* norm `w_i‖U_i^k‖ ≥ 0`. `m` is the
/// expected participation budget, `1 ≤ m ≤ n`.
///
/// Degenerate inputs follow the paper's conventions:
/// * all-zero norms → uniform `p_i = m/n` (any sampling has variance 0);
/// * clients with `ũ_i = 0` get `p_i = 0` — their update contributes
///   nothing, so unbiasedness is unaffected (`w_i U_i = 0` a.s.).
pub fn ocs_probabilities(norms: &[f64], m: usize) -> OcsProbs {
    let n = norms.len();
    assert!(m >= 1 && m <= n, "budget m={m} out of range for n={n}");
    assert!(
        norms.iter().all(|&u| u.is_finite() && u >= 0.0),
        "norms must be finite and non-negative"
    );

    let total: f64 = norms.iter().sum();
    if total <= 0.0 {
        return OcsProbs { probs: vec![m as f64 / n as f64; n], l: n, capped: 0 };
    }

    // Ascending sort of packed (norm, index) pairs. Packing beats an
    // indirect argsort ~2× at n = 10⁶: comparisons read the key from the
    // element being moved instead of chasing `norms[i]` (EXPERIMENTS.md
    // §Perf L3-1).
    let mut pairs: Vec<(f64, u32)> = norms
        .iter()
        .enumerate()
        .map(|(i, &u)| (u, i as u32))
        .collect();
    pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let mut prefix = vec![0.0f64; n + 1];
    for (rank, &(u, _)) in pairs.iter().enumerate() {
        prefix[rank + 1] = prefix[rank] + u;
    }

    // Largest feasible l, scanning down from n (at most m iterations).
    let mut l = n;
    loop {
        let c = (m + l) as f64 - n as f64; // m + l - n
        if c > 0.0 && c * pairs[l - 1].0 <= prefix[l] * (1.0 + 1e-12) {
            break;
        }
        l -= 1;
        debug_assert!(l + m >= n, "l search passed the guaranteed bound");
    }

    let c = (m + l) as f64 - n as f64;
    let denom = prefix[l];
    // NB: keep the `c * u / denom` form — hoisting `c/denom` loses the
    // exact p = 1.0 on boundary clients (u == S_l/c) to rounding, which
    // breaks the α = 0 sparse-profile guarantee the tests pin down.
    let mut probs = vec![0.0f64; n];
    for (rank, &(u, idx)) in pairs.iter().enumerate() {
        probs[idx as usize] = if rank < l {
            if denom > 0.0 {
                (c * u / denom).min(1.0)
            } else {
                0.0
            }
        } else {
            1.0
        };
    }
    OcsProbs { probs, l, capped: n - l }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{norm_profile, quick};

    fn expected_size(p: &[f64]) -> f64 {
        p.iter().sum()
    }

    #[test]
    fn all_equal_norms_give_uniform() {
        let p = ocs_probabilities(&[2.0; 10], 3).probs;
        for &pi in &p {
            assert!((pi - 0.3).abs() < 1e-12, "{p:?}");
        }
    }

    #[test]
    fn all_zero_norms_fall_back_to_uniform() {
        let p = ocs_probabilities(&[0.0; 8], 2).probs;
        for &pi in &p {
            assert!((pi - 0.25).abs() < 1e-12);
        }
    }

    #[test]
    fn single_dominant_client_is_capped() {
        let r = ocs_probabilities(&[100.0, 1.0, 1.0], 2);
        assert_eq!(r.capped, 1);
        assert!((r.probs[0] - 1.0).abs() < 1e-12);
        assert!((r.probs[1] - 0.5).abs() < 1e-12);
        assert!((r.probs[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn at_most_m_nonzero_all_get_one() {
        // ≤ m clients with non-zero updates => variance can reach 0
        let r = ocs_probabilities(&[0.0, 5.0, 0.0, 3.0, 0.0], 2);
        assert!((r.probs[1] - 1.0).abs() < 1e-12);
        assert!((r.probs[3] - 1.0).abs() < 1e-12);
        assert_eq!(r.probs[0], 0.0);
        assert_eq!(r.probs[2], 0.0);
    }

    #[test]
    fn m_equals_n_gives_full_participation() {
        let r = ocs_probabilities(&[3.0, 1.0, 7.0, 0.5], 4);
        for &pi in &r.probs {
            assert!((pi - 1.0).abs() < 1e-12, "{:?}", r.probs);
        }
    }

    #[test]
    fn m_equals_one_matches_zhao_zhang() {
        // m=1 recovers Zhao & Zhang (2015): p_i ∝ ũ_i
        let norms = [1.0, 2.0, 3.0, 4.0];
        let r = ocs_probabilities(&norms, 1);
        let total: f64 = norms.iter().sum();
        for (pi, ui) in r.probs.iter().zip(&norms) {
            assert!((pi - ui / total).abs() < 1e-12);
        }
    }

    #[test]
    fn proportional_when_no_cap_needed() {
        let norms = [1.0, 1.0, 1.0, 3.0];
        // m=2: 2*3/6 = 1.0 exactly — boundary: still l = n
        let r = ocs_probabilities(&norms, 2);
        assert!((r.probs[3] - 1.0).abs() < 1e-9);
        assert!((r.probs[0] - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(r.l, 4);
    }

    #[test]
    #[should_panic(expected = "budget m=0")]
    fn zero_budget_rejected() {
        ocs_probabilities(&[1.0], 0);
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn negative_norm_rejected() {
        ocs_probabilities(&[1.0, -0.5], 1);
    }

    #[test]
    fn prop_probabilities_valid_and_budget_respected() {
        quick("ocs-valid", |rng, _| {
            let n = rng.range(1, 64);
            let m = rng.range(1, n + 1);
            let norms = norm_profile(rng, n);
            let r = ocs_probabilities(&norms, m);
            for &p in &r.probs {
                if !(0.0..=1.0 + 1e-12).contains(&p) {
                    return Err(format!("p={p} out of range"));
                }
            }
            let b = expected_size(&r.probs);
            if b > m as f64 + 1e-6 {
                return Err(format!("budget violated: Σp={b} > m={m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_budget_tight_when_all_positive() {
        // With every norm > 0 the optimum saturates the constraint Σp = m.
        quick("ocs-tight", |rng, _| {
            let n = rng.range(2, 64);
            let m = rng.range(1, n + 1);
            let norms: Vec<f64> =
                (0..n).map(|_| 0.05 + rng.exponential(0.5)).collect();
            let r = ocs_probabilities(&norms, m);
            let b = expected_size(&r.probs);
            if (b - m as f64).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("Σp={b} != m={m}"))
            }
        });
    }

    #[test]
    fn prop_monotone_in_norms() {
        // larger ũ_i ⇒ p_i at least as large
        quick("ocs-monotone", |rng, _| {
            let n = rng.range(2, 40);
            let m = rng.range(1, n + 1);
            let norms = norm_profile(rng, n);
            let r = ocs_probabilities(&norms, m);
            for i in 0..n {
                for j in 0..n {
                    if norms[i] > norms[j] && r.probs[i] + 1e-12 < r.probs[j] {
                        return Err(format!(
                            "monotonicity broken: u{i}={} p{i}={} vs u{j}={} p{j}={}",
                            norms[i], r.probs[i], norms[j], r.probs[j]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_optimality_vs_random_feasible() {
        // OCS variance never exceeds the variance of random feasible probs.
        use crate::sampling::variance::sampling_variance;
        quick("ocs-optimal", |rng, _| {
            let n = rng.range(2, 24);
            let m = rng.range(1, n + 1);
            let norms: Vec<f64> =
                (0..n).map(|_| rng.exponential(0.5) + 0.01).collect();
            let opt = ocs_probabilities(&norms, m);
            let v_opt = sampling_variance(&norms, &opt.probs);
            // random feasible point: dirichlet scaled into the budget
            let mut q: Vec<f64> =
                rng.dirichlet(1.0, n).iter().map(|&d| d * m as f64).collect();
            for qi in &mut q {
                *qi = qi.clamp(1e-6, 1.0);
            }
            // keep q strictly inside the budget so it cannot beat the
            // optimum by borrowing extra expected participants
            let s: f64 = q.iter().sum();
            if s > m as f64 {
                for qi in &mut q {
                    *qi *= m as f64 / s;
                }
            }
            let v_q = sampling_variance(&norms, &q);
            if v_opt <= v_q + 1e-9 + v_q.abs() * 1e-9 {
                Ok(())
            } else {
                Err(format!("v_opt={v_opt} > v_q={v_q}"))
            }
        });
    }
}
