//! Sampling-variance machinery: Eq. (6), the improvement factor α^k
//! (Definition 11) and the relative improvement factor γ^k (Eq. 16).

use super::ocs::ocs_probabilities;

/// Sampling variance of an independent sampling with probabilities `p`
/// over weighted norms `ũ` (Eq. 6): `Σ_i (1−p_i)/p_i · ũ_i²`.
///
/// Clients with `ũ_i = 0` contribute nothing regardless of `p_i`
/// (including `p_i = 0`); a zero probability on a non-zero norm is an
/// improper sampling and returns infinity.
pub fn sampling_variance(norms: &[f64], probs: &[f64]) -> f64 {
    assert_eq!(norms.len(), probs.len());
    let mut acc = 0.0f64;
    for (&u, &p) in norms.iter().zip(probs) {
        if u == 0.0 {
            continue;
        }
        if p <= 0.0 {
            return f64::INFINITY;
        }
        acc += (1.0 - p) / p * u * u;
    }
    acc
}

/// Variance of independent *uniform* sampling with p_i = m/n.
pub fn uniform_variance(norms: &[f64], m: usize) -> f64 {
    let n = norms.len();
    assert!(m >= 1 && m <= n);
    let sum_sq: f64 = norms.iter().map(|u| u * u).sum();
    (n as f64 - m as f64) / m as f64 * sum_sq
}

/// Improvement factor α^k (Definition 11): optimal variance / uniform
/// variance for this round's norms. α ∈ [0, 1]; 0 when ≤ m non-zero
/// updates (optimal behaves like full participation), 1 when all norms
/// are equal (nothing beats uniform).
pub fn improvement_factor(norms: &[f64], m: usize) -> f64 {
    let vu = uniform_variance(norms, m);
    if vu <= 0.0 {
        return 0.0; // all norms zero, or m = n — any sampling is exact
    }
    let probs = ocs_probabilities(norms, m).probs;
    (sampling_variance(norms, &probs) / vu).clamp(0.0, 1.0)
}

/// Relative improvement factor γ^k = m / (α^k(n − m) + m) (Eq. 16).
/// γ ∈ [m/n, 1]: 1 ⇔ full-participation-like, m/n ⇔ uniform-like.
pub fn gamma(alpha: f64, n: usize, m: usize) -> f64 {
    assert!(m >= 1 && m <= n);
    m as f64 / (alpha * (n - m) as f64 + m as f64)
}

/// Effective number of uniformly-sampled clients the round is worth
/// (the paper's intuition: OCS with budget m behaves like uniform
/// sampling with m̃ = γ·n ∈ [m, n] clients).
pub fn effective_clients(alpha: f64, n: usize, m: usize) -> f64 {
    gamma(alpha, n, m) * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::probability::draw_independent;
    use crate::util::prop::{norm_profile, quick};
    use crate::util::rng::Rng;

    #[test]
    fn variance_zero_at_full_participation() {
        let norms = [3.0, 1.0, 2.0];
        assert_eq!(sampling_variance(&norms, &[1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn improper_sampling_is_infinite() {
        assert!(sampling_variance(&[1.0], &[0.0]).is_infinite());
    }

    #[test]
    fn zero_norm_ignores_probability() {
        assert_eq!(sampling_variance(&[0.0, 2.0], &[0.0, 1.0]), 0.0);
    }

    #[test]
    fn uniform_variance_formula() {
        // (n-m)/m Σu² with n=4, m=2, Σu²=30 → 30
        let v = uniform_variance(&[1.0, 2.0, 3.0, 4.0], 2);
        assert!((v - 30.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_one_for_equal_norms() {
        let a = improvement_factor(&[2.0; 8], 3);
        assert!((a - 1.0).abs() < 1e-9, "alpha={a}");
    }

    #[test]
    fn alpha_zero_for_sparse_updates() {
        // ≤ m non-zero norms → OCS variance 0 → α = 0
        let a = improvement_factor(&[0.0, 7.0, 0.0, 0.0, 1.0, 0.0], 2);
        assert_eq!(a, 0.0);
    }

    #[test]
    fn gamma_bounds_and_edges() {
        assert!((gamma(1.0, 32, 4) - 4.0 / 32.0).abs() < 1e-12);
        assert!((gamma(0.0, 32, 4) - 1.0).abs() < 1e-12);
        let g = gamma(0.5, 32, 4);
        assert!(g > 4.0 / 32.0 && g < 1.0);
        assert!((effective_clients(0.0, 32, 4) - 32.0).abs() < 1e-9);
    }

    #[test]
    fn prop_alpha_in_unit_interval() {
        quick("alpha-range", |rng, _| {
            let n = rng.range(2, 64);
            let m = rng.range(1, n); // m < n so uniform variance > 0
            let norms = norm_profile(rng, n);
            let a = improvement_factor(&norms, m);
            if (0.0..=1.0).contains(&a) {
                Ok(())
            } else {
                Err(format!("alpha={a}"))
            }
        });
    }

    #[test]
    fn empirical_estimator_is_unbiased_and_variance_matches_eq6() {
        // Monte-Carlo check of Lemma 1 equality for independent sampling:
        // E‖Σ_{i∈S} ũ_i/p_i − Σ ũ_i‖² == Σ (1−p_i)/p_i ũ_i² (scalar case)
        let norms = [5.0, 2.0, 1.0, 0.5, 0.25, 3.0];
        let m = 3;
        let probs = ocs_probabilities(&norms, m).probs;
        let target: f64 = norms.iter().sum();
        let mut rng = Rng::new(99);
        let trials = 200_000;
        let mut mean_est = 0.0f64;
        let mut second = 0.0f64;
        for _ in 0..trials {
            let sel = draw_independent(&probs, &mut rng);
            let est: f64 = sel
                .iter()
                .zip(norms.iter().zip(&probs))
                .filter(|(s, _)| **s)
                .map(|(_, (u, p))| u / p)
                .sum();
            mean_est += est;
            let d = est - target;
            second += d * d;
        }
        mean_est /= trials as f64;
        second /= trials as f64;
        let predicted = sampling_variance(&norms, &probs);
        assert!(
            (mean_est - target).abs() / target < 0.01,
            "biased: {mean_est} vs {target}"
        );
        assert!(
            (second - predicted).abs() / predicted < 0.05,
            "variance mismatch: {second} vs {predicted}"
        );
    }
}
