//! Independent-sampling machinery: Bernoulli set draws and the
//! probability matrix P_{ij} = Prob({i,j} ⊆ S) (Section 2).

use crate::util::rng::Rng;

/// Draw an independent sampling S: include i with probability p_i.
pub fn draw_independent(probs: &[f64], rng: &mut Rng) -> Vec<bool> {
    probs.iter().map(|&p| rng.bernoulli(p)).collect()
}

/// Indices of the drawn set.
pub fn draw_indices(probs: &[f64], rng: &mut Rng) -> Vec<usize> {
    probs
        .iter()
        .enumerate()
        .filter(|(_, &p)| rng.bernoulli(p))
        .map(|(i, _)| i)
        .collect()
}

/// The probability matrix of an *independent* sampling:
/// `P_ij = p_i p_j` off-diagonal, `P_ii = p_i` (row-major, n×n).
pub fn independent_prob_matrix(probs: &[f64]) -> Vec<f64> {
    let n = probs.len();
    let mut mat = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            mat[i * n + j] = if i == j {
                probs[i]
            } else {
                probs[i] * probs[j]
            };
        }
    }
    mat
}

/// Expected sample size b = Trace(P) = Σ p_i.
pub fn expected_size(probs: &[f64]) -> f64 {
    probs.iter().sum()
}

/// Whether the sampling is proper (p_i > 0 ∀i). The paper's estimator
/// requires properness except on zero-norm clients.
pub fn is_proper(probs: &[f64]) -> bool {
    probs.iter().all(|&p| p > 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;

    #[test]
    fn draw_respects_edge_probabilities() {
        let mut rng = Rng::new(4);
        let probs = [0.0, 1.0, 0.5];
        let mut counts = [0usize; 3];
        let trials = 40_000;
        for _ in 0..trials {
            for (c, s) in counts.iter_mut().zip(draw_independent(&probs, &mut rng))
            {
                *c += s as usize;
            }
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], trials);
        let f = counts[2] as f64 / trials as f64;
        assert!((f - 0.5).abs() < 0.02, "{f}");
    }

    #[test]
    fn indices_match_bools() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let probs = [0.3, 0.9, 0.1, 0.7];
        let bools = draw_independent(&probs, &mut r1);
        let idx = draw_indices(&probs, &mut r2);
        let from_bools: Vec<usize> = bools
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(idx, from_bools);
    }

    #[test]
    fn prob_matrix_diag_and_symmetry() {
        let p = [0.2, 0.5, 1.0];
        let m = independent_prob_matrix(&p);
        for i in 0..3 {
            assert_eq!(m[i * 3 + i], p[i]);
            for j in 0..3 {
                assert_eq!(m[i * 3 + j], m[j * 3 + i]);
            }
        }
        assert!((m[1] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn trace_is_expected_size() {
        quick("trace-b", |rng, _| {
            let n = rng.range(1, 20);
            let p: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let m = independent_prob_matrix(&p);
            let trace: f64 = (0..n).map(|i| m[i * n + i]).sum();
            if (trace - expected_size(&p)).abs() < 1e-9 {
                Ok(())
            } else {
                Err("trace != Σp".into())
            }
        });
    }

    #[test]
    fn empirical_set_size_matches_b() {
        let probs: Vec<f64> = (0..20).map(|i| (i as f64 + 1.0) / 40.0).collect();
        let b = expected_size(&probs);
        let mut rng = Rng::new(12);
        let trials = 30_000;
        let total: usize = (0..trials)
            .map(|_| draw_indices(&probs, &mut rng).len())
            .sum();
        let mean = total as f64 / trials as f64;
        assert!((mean - b).abs() < 0.08, "mean={mean} b={b}");
    }

    #[test]
    fn properness() {
        assert!(is_proper(&[0.1, 1.0]));
        assert!(!is_proper(&[0.1, 0.0]));
    }
}
